"""Model configuration.

One :class:`ModelConfig` describes every architecture family in the zoo:
dense GQA decoders, MoE (incl. DeepSeek-V3 MLA + shared/routed experts),
SSM (xLSTM sLSTM/mLSTM), hybrid (RecurrentGemma RG-LRU + local attention),
audio encoder-decoder (Seamless backbone) and VLM (LLaVA-NeXT backbone).

The per-layer block sequence is expressed as a cyclic ``block_pattern``;
layer ``i`` gets ``block_pattern[i % len(block_pattern)]``.  Block types:

* ``"attn"``        full-causal GQA attention
* ``"swa"``         sliding-window GQA attention (``sliding_window``)
* ``"local"``       RecurrentGemma-style local attention (``local_window``)
* ``"mla"``         DeepSeek multi-head latent attention
* ``"rglru"``       RecurrentGemma Griffin recurrent block (conv + RG-LRU)
* ``"mlstm"``       xLSTM matrix-memory LSTM block
* ``"slstm"``       xLSTM scalar-memory LSTM block

Every attention-ish block is followed by the config's FFN (dense SwiGLU or
MoE); recurrent xLSTM blocks embed their own projections (``d_ff == 0``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    num_shared_experts: int = 0
    d_ff_expert: int = 0            # expert hidden dim (falls back to d_ff)
    router_aux_loss_coef: float = 0.01
    # Baseline dispatch is dense one-hot einsum (XLA lowers to all-gather);
    # "a2a" switches to the shard_map all-to-all schedule (perf hillclimb).
    dispatch: str = "einsum"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims [arXiv:2412.19437]."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    citation: str = ""

    head_dim: Optional[int] = None   # None -> d_model // num_heads
    block_pattern: Tuple[str, ...] = ("attn",)
    qkv_bias: bool = False
    sliding_window: int = 4096       # for "swa" blocks
    local_window: int = 2048         # for "local" blocks
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0       # recurrentgemma uses 30.0

    # --- MoE ---
    moe: Optional[MoEConfig] = None
    # --- MLA ---
    mla: Optional[MLAConfig] = None
    # --- DeepSeek multi-token prediction: number of extra MTP heads ---
    mtp_depth: int = 0

    # --- recurrent (rglru / xlstm) ---
    rnn_width: Optional[int] = None  # RG-LRU lru width (None -> d_model)
    conv_width: int = 4              # temporal conv in Griffin block
    # xLSTM: mLSTM up-projection factor; block owns its FFN when d_ff == 0
    mlstm_proj_factor: float = 2.0
    # chunked-remat time scan for mLSTM (0 = off): carries (the per-step
    # matrix memory C) are stored only at chunk boundaries and recomputed
    # within chunks during backward — the §Perf memory hillclimb for
    # xlstm train shapes.
    mlstm_chunk: int = 0

    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0          # >0 => encoder-decoder model
    # --- modality frontend stub: embeddings arrive precomputed ---
    frontend: Optional[str] = None   # None | "audio" | "vision"
    num_prefix_tokens: int = 0       # VLM image-patch tokens per sample

    # --- numerics ---
    dtype: str = "float32"           # activation/param dtype for lowering

    # per-layer activation rematerialization (jax.checkpoint around each
    # block in the scan): the standard production memory/compute trade —
    # backward recomputes block internals instead of storing them.
    remat: bool = True

    # --- distribution ---
    # ZeRO-3-style FSDP over the data axis. For configs whose params+
    # Adam state exceed HBM with tensor-parallel alone.  Mutually
    # exclusive with using the data axis as an
    # EnFed client axis: fsdp configs federate over the pod axis instead
    # (see DESIGN.md §Arch-applicability).
    fsdp: bool = False

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    def block_type(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    @property
    def layer_types(self) -> Tuple[str, ...]:
        return tuple(self.block_type(i) for i in range(self.num_layers))

    @property
    def supports_long_decode(self) -> bool:
        """True if decode state is o(seq): recurrent state and/or windowed KV."""
        quad = {"attn", "mla"}
        return all(t not in quad for t in self.layer_types)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Reduced variant used by CPU smoke tests: same family/pattern, tiny dims.
    def smoke(self) -> "ModelConfig":
        pat = len(self.block_pattern)
        layers = max(2, pat) if pat > 1 else 2
        kw = dict(
            num_layers=layers,
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 1,
            head_dim=32,
            d_ff=0 if self.d_ff == 0 else 256,
            vocab_size=512,
            encoder_layers=2 if self.encoder_layers else 0,
            sliding_window=64,
            local_window=64,
            rnn_width=128 if self.rnn_width else None,
            num_prefix_tokens=8 if self.num_prefix_tokens else 0,
            mtp_depth=min(self.mtp_depth, 1),
            dtype="float32",
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                num_experts_per_tok=min(self.moe.num_experts_per_tok, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                d_ff_expert=128,
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32,
                qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
            )
        return self.replace(**kw)
