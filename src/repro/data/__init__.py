from repro.data.har import (
    make_har_windows,
    make_calories_tabular,
    HARDatasetConfig,
    CaloriesDatasetConfig,
)
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.loader import batch_iterator, train_test_split
from repro.data.tokens import synthetic_token_batches

__all__ = [
    "make_har_windows",
    "make_calories_tabular",
    "HARDatasetConfig",
    "CaloriesDatasetConfig",
    "dirichlet_partition",
    "iid_partition",
    "batch_iterator",
    "train_test_split",
    "synthetic_token_batches",
]
