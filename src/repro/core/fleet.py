"""Jit-native EnFed fleet engine: many concurrent requester sessions,
one compiled program, allocation- and transfer-lean.

The loop engine (``repro.core.rounds.EnFedSession``) executes Algorithm 1
as Python control flow — one ``task.fit`` dispatch per contributor per
round — which caps simulations at a handful of sessions.  This module
ports the same protocol onto stacked arrays so an entire fleet of
requesting devices advances together.  Three design rules keep the hot
path lean at R=512 and beyond:

* **Flat-parameter round state.**  Contributor params are raveled ONCE
  at setup (``repro.utils.tree.tree_ravel``) into a single (R, N, P)
  fp32 buffer — R requester sessions, N contributor slots, P flat model
  parameters.  That buffer IS the round state: the batched Pallas
  ``fedavg`` kernel (eq. 14 for every session, one launch) reads it
  directly, masked freezes are plain ``jnp.where`` on it, and it is
  donated to XLA (``donate_argnames``) so the round loop updates it in
  place.  Pytrees reappear only inside the per-device ``fit_one`` /
  ``eval_one`` views (``tree_unravel`` on a lane's (P,) slice) and at
  the host boundary when results are unpacked.

* **On-device minibatch scheduling.**  No index tensors are staged:
  batches come from the counter-based derived schedule
  (``repro.core.schedule``), evaluated inside the compiled round loop
  from the traced round number.  The loop engine's ``SupervisedTask.fit``
  evaluates the SAME derivation host-side, so both engines see identical
  batches by construction; prefix-stable per-sample scores make one
  traced program serve requesters with different shard sizes, including
  shards smaller than one batch (single padded step, zero-weight
  padding).  The old host plan was a (max_rounds, R, epochs, steps,
  batch) int32 tensor — at R=512 it dominated host RAM and host->device
  bytes; it no longer exists.

* **Early-exit rounds, no dead work.**  The round loop is a chunked
  ``lax.while_loop``: after every ``round_chunk`` rounds the program
  checks whether any lane is still active and stops outright when the
  whole fleet is done, so a fleet that converges by round k executes
  O(k) round bodies, not ``max_rounds``.  Inside a chunk, each round
  body sits under ``lax.cond`` — once every lane has stopped (or the
  chunk runs past ``max_rounds``) the fit/refresh compute is skipped,
  not computed-and-discarded; the contributor refresh is additionally
  gated on any lane surviving into the next round.  Because traces are
  preallocated (max_rounds, R) buffers written in place, early exit
  leaves the untouched tail at zero — ``history["round_executed"]``
  records exactly which round bodies ran.

Phase mapping (vocabulary in ``repro.core.protocol``): handshake stays
host-side (cheap, deterministic numpy) and emits the (R, N) contract
mask + static per-round aggregation weights; collect+aggregate is the
batched fedavg launch on the flat buffer; fit/score/account are vmapped
masked lanes; refresh trains contributors on their own shards between
rounds (frozen once their requester stops).

Parity with the loop engine — same aggregated params, round counts, stop
reasons, and battery trajectories — is asserted by
``tests/test_fleet_engine.py`` across aggregation strategies and
encrypt on/off.  The AES-128-CTR transport is bit-exact (validated in
the loop engine / kernel tests), so the fleet engine models encryption
in the cost domain (byte counts -> eq. (4)-(7) -> battery) without
re-running the cipher per round.  All sessions share one
``SupervisedTask``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol, schedule
from repro.core.battery import BatteryState, discharge_level, load_efficiency
from repro.core.energy import CostModel
from repro.core.incentive import NeighborDevice, sign_contracts_fleet
from repro.core.rounds import EnFedConfig, SessionResult
from repro.kernels.fedavg.ops import fedavg_flat_batched
from repro.models.classifiers import masked_cross_entropy_loss
from repro.optim import apply_updates
from repro.utils.tree import (tree_bytes, tree_ravel, tree_size, tree_unravel,
                              tree_where)


@dataclasses.dataclass
class RequesterSpec:
    """One requesting device's inputs, mirroring ``EnFedSession``'s."""

    own_train: tuple                      # (x, y) numpy/array shard
    own_test: tuple
    neighborhood: Sequence[NeighborDevice]
    contributor_states: Dict[int, dict]   # device_id -> {params, data}
    battery: Optional[BatteryState] = None


@dataclasses.dataclass
class FleetResult:
    """Stacked outcome of one fleet program plus per-session views."""

    sessions: List[SessionResult]
    rounds: np.ndarray          # (R,) executed rounds per session
    stop_codes: np.ndarray      # (R,) protocol.STOP_* codes
    accuracy: np.ndarray        # (R,) final accuracy
    battery_level: np.ndarray   # (R,) final battery fraction
    total_energy_j: float       # summed eq. (5) energy across the fleet
    history: Dict[str, np.ndarray]  # (max_rounds, R) traces; "round_executed"
                                    # is (max_rounds,) — 1 where a round body ran
    staged_host_bytes: int = 0  # host->device bytes staged for the program
    staged_index_bytes: int = 0  # subset that is minibatch-schedule metadata


def _pad_stack(arrays, pad_len: int):
    """Stack ragged leading-axis arrays into (R, pad_len, ...) + mask."""
    shape = arrays[0].shape[1:]
    out = np.zeros((len(arrays), pad_len) + shape, arrays[0].dtype)
    mask = np.zeros((len(arrays), pad_len), np.float32)
    for i, a in enumerate(arrays):
        out[i, :len(a)] = a
        mask[i, :len(a)] = 1.0
    return out, mask


def _stack_trees(trees, template=None):
    """List of pytrees -> pytree with leading stacked axis (None entries
    become zeros_like(template))."""
    template = template if template is not None else next(t for t in trees if t is not None)
    filled = [t if t is not None else jax.tree_util.tree_map(np.zeros_like, template)
              for t in trees]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
                                  *filled)


@functools.partial(
    jax.jit,
    static_argnames=("task", "use_pallas", "interpret", "do_refresh", "chunk",
                     "max_rounds", "epochs", "batch", "steps_max",
                     "ref_epochs", "ref_steps", "spec"),
    donate_argnames=("contrib_flat",))
def _fleet_program(task, use_pallas, interpret, do_refresh, chunk, max_rounds,
                   epochs, batch, steps_max, ref_epochs, ref_steps, spec,
                   contrib_flat, arrays):
    """The whole fleet's Algorithm 1 as one compiled program.

    Module-level so the jit cache is shared across ``run_fleet`` calls:
    re-running with the same ``task`` (id-hashed static) and the same
    array shapes — e.g. parametrized parity tests sweeping strategies,
    encryption, or stopping thresholds, all of which are traced inputs
    (``round_w``, ``e_round``, ``desired_accuracy``...) — reuses the
    compiled executable instead of re-tracing per call.

    ``contrib_flat`` (R, N, P) is the donated flat round state;
    ``spec`` is the static :func:`repro.utils.tree.tree_ravel` spec that
    recovers per-device parameter pytrees from (P,) lane views.
    """
    model, opt = task.model, task._opt
    R, N, P = contrib_flat.shape
    n_pad = arrays["own_x"].shape[1]

    def fit_one(flat_p, x, y, idx, w):
        """Identical math to SupervisedTask.fit for one device's shard,
        on a flat (P,) parameter view."""
        E, S, B = idx.shape
        params = tree_unravel(spec, flat_p)

        def one_step(carry, sv):
            p, s = carry
            ib, wb = sv
            xb, yb = x[ib], y[ib]
            loss, grads = jax.value_and_grad(
                lambda pp: masked_cross_entropy_loss(
                    model.forward(pp, xb), yb, wb))(p)
            upd, s2 = opt.update(grads, s, p)
            p2 = apply_updates(p, upd)
            take = jnp.sum(wb) > 0
            return ((tree_where(take, p2, p), tree_where(take, s2, s)),
                    jnp.where(take, loss, 0.0))

        (params, _), losses = jax.lax.scan(
            one_step, (params, opt.init(params)),
            (idx.reshape(E * S, B), w.reshape(E * S, B)))
        valid_steps = (w.sum(-1) > 0).astype(jnp.float32).reshape(E, S).sum(1)
        per_epoch = losses.reshape(E, S).sum(1) / jnp.maximum(valid_steps, 1.0)
        flat_out, _ = tree_ravel(params)
        return flat_out, per_epoch[-1]

    def eval_one(flat_p, x, y, mask):
        logits = model.forward(tree_unravel(spec, flat_p), x)
        correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        return jnp.sum(correct * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    if do_refresh:
        # Phase.REFRESH schedule is round-invariant (seed = cfg.seed +
        # device_id), so its indices are derived once per program, on
        # device, and reused every round.
        nc_pad = arrays["cx"].shape[2]
        ref_scores = jax.vmap(jax.vmap(
            lambda s: schedule.epoch_scores(s, ref_epochs, nc_pad)))(
            arrays["ref_seeds"])
        ref_idx, ref_w = jax.vmap(jax.vmap(
            lambda sc, n: schedule.plan_from_scores(sc, n, batch, ref_steps)))(
            ref_scores, arrays["ref_n"])
        cxf = arrays["cx"].reshape((R * N,) + arrays["cx"].shape[2:])
        cyf = arrays["cy"].reshape(R * N, -1)
        ref_idx = ref_idx.reshape(R * N, ref_epochs, ref_steps, batch)
        ref_w = ref_w.reshape(R * N, ref_epochs, ref_steps, batch)

    def run_round(state, rr):
        """One live round body.  Entered only via lax.cond when at least
        one lane is active and rr < max_rounds (so ``active`` needs no
        extra validity masking inside)."""
        (contrib, last, level, active, stop_code, rounds_done,
         acc_h, loss_h, bat_h, exec_h, body_h) = state

        # Phase.COLLECT + Phase.AGGREGATE: one batched kernel launch,
        # directly on the flat (R, N, P) round state.
        glob = fedavg_flat_batched(contrib, arrays["round_w"],
                                   use_pallas=use_pallas, interpret=interpret)

        # Phase.FIT (requesters personalize) + Phase.SCORE.  The round's
        # minibatch indices are derived here, on device, from the traced
        # round number — nothing was staged from the host.
        scores = schedule.epoch_scores(arrays["seed0"] + rr, epochs, n_pad)
        idx, w = jax.vmap(
            lambda n: schedule.plan_from_scores(scores, n, batch, steps_max))(
            arrays["n_own"])
        new_flat, last_loss = jax.vmap(fit_one)(
            glob, arrays["own_x"], arrays["own_y"], idx, w)
        acc = jax.vmap(eval_one)(new_flat, arrays["test_x"], arrays["test_y"],
                                 arrays["test_mask"])

        # Phase.ACCOUNT: traced battery discharge for executed rounds
        level_new = discharge_level(level, arrays["e_round"],
                                    arrays["capacity"], arrays["eff"])
        reached = acc >= arrays["desired_accuracy"]
        low = level_new < arrays["battery_threshold"]
        stop_code = jnp.where(active & reached, protocol.STOP_ACCURACY,
                              jnp.where(active & ~reached & low,
                                        protocol.STOP_BATTERY, stop_code))
        level = jnp.where(active, level_new, level)
        rounds_done = rounds_done + active.astype(jnp.int32)
        last = jnp.where(active[:, None], new_flat, last)
        next_active = active & ~reached & ~low

        # Phase.REFRESH: contributors keep training (frozen once their
        # requester stops); skipped entirely — not computed-and-masked —
        # when no lane survives into the next round.
        if do_refresh:
            def refresh(c):
                refreshed, _ = jax.vmap(fit_one)(
                    c.reshape(R * N, P), cxf, cyf, ref_idx, ref_w)
                return jnp.where(next_active[:, None, None],
                                 refreshed.reshape(R, N, P), c)

            contrib = jax.lax.cond(jnp.any(next_active), refresh,
                                   lambda c: c, contrib)

        def put(buf, row):
            return jax.lax.dynamic_update_slice_in_dim(buf, row[None], rr, 0)

        acc_h = put(acc_h, acc)
        loss_h = put(loss_h, last_loss)
        bat_h = put(bat_h, level)
        exec_h = put(exec_h, active.astype(jnp.float32))
        body_h = put(body_h, jnp.float32(1.0))
        return (contrib, last, level, next_active, stop_code, rounds_done,
                acc_h, loss_h, bat_h, exec_h, body_h)

    state0 = (contrib_flat,
              jnp.zeros((R, P), contrib_flat.dtype),
              arrays["level0"],
              jnp.ones((R,), bool),
              jnp.full((R,), protocol.STOP_MAX_ROUNDS, jnp.int32),
              jnp.zeros((R,), jnp.int32),
              jnp.zeros((max_rounds, R), jnp.float32),   # accuracy trace
              jnp.zeros((max_rounds, R), jnp.float32),   # loss trace
              jnp.zeros((max_rounds, R), jnp.float32),   # battery trace
              jnp.zeros((max_rounds, R), jnp.float32),   # active-lane trace
              jnp.zeros((max_rounds,), jnp.float32))     # body-executed trace

    def maybe_round(i, carry):
        r0, state = carry
        rr = r0 + i
        state = jax.lax.cond((rr < max_rounds) & jnp.any(state[3]),
                             lambda s: run_round(s, rr), lambda s: s, state)
        return r0, state

    def while_cond(carry):
        r0, state = carry
        return (r0 < max_rounds) & jnp.any(state[3])

    def while_body(carry):
        r0, state = carry
        _, state = jax.lax.fori_loop(0, chunk, maybe_round, (r0, state))
        return r0 + chunk, state

    _, state = jax.lax.while_loop(while_cond, while_body,
                                  (jnp.int32(0), state0))
    (contrib, last, level, _, stop_code, rounds_done,
     acc_h, loss_h, bat_h, exec_h, body_h) = state
    return (contrib, last, level, stop_code, rounds_done,
            (acc_h, loss_h, bat_h, exec_h, body_h))


def run_fleet(task, requesters: Sequence[RequesterSpec],
              cfg: EnFedConfig = EnFedConfig(),
              cost_model: Optional[CostModel] = None,
              use_pallas: bool = True,
              interpret: Optional[bool] = None,
              round_chunk: int = 4) -> FleetResult:
    """Run ``len(requesters)`` concurrent EnFed sessions as one jit program.

    ``interpret`` selects Pallas interpret mode for the aggregation
    kernel (``None`` = compiled on TPU, interpreted on CPU — see
    ``repro.kernels.common.resolve_interpret``).  ``round_chunk`` is the
    early-exit granularity: the compiled round loop re-checks "is any
    session still active?" every ``round_chunk`` rounds.
    """
    from repro.kernels.common import resolve_interpret

    cost = cost_model or CostModel()
    R = len(requesters)
    if R == 0:
        raise ValueError("empty fleet")
    if round_chunk < 1:
        raise ValueError(f"round_chunk must be >= 1 (got {round_chunk})")

    # ---- Phase.HANDSHAKE (host-side, static) ------------------------------
    contracts, contract_mask = sign_contracts_fleet(
        [spec.neighborhood for spec in requesters],
        cfg.offered_incentive, cfg.n_max)
    for i, cs in enumerate(contracts):
        if not cs:
            raise RuntimeError(
                f"requester {i}: no nearby device agreed to the incentive (N_d < 1)")
    N = contract_mask.shape[1]

    # per-round aggregation weights = contract mask x strategy round mask
    round_w = np.zeros((R, N), np.float32)
    for i, cs in enumerate(contracts):
        round_w[i, :len(cs)] = protocol.round_weights(len(cs), cfg.strategy)

    # ---- contributor state / data stacks ----------------------------------
    template = requesters[0].contributor_states[
        contracts[0][0].device_id]["params"]
    contrib_params, contrib_x, contrib_y = [], [], []
    for spec, cs in zip(requesters, contracts):
        row_p, row_x, row_y = [], [], []
        for c in cs:
            st = spec.contributor_states[c.device_id]
            row_p.append(st["params"])
            row_x.append(np.asarray(st["data"][0]))
            row_y.append(np.asarray(st["data"][1]).astype(np.int32))
        contrib_params.append(row_p)
        contrib_x.append(row_x)
        contrib_y.append(row_y)

    n_c_max = max(max(len(x) for x in row) for row in contrib_x)
    cx = np.zeros((R, N, n_c_max) + contrib_x[0][0].shape[1:], np.float32)
    cy = np.zeros((R, N, n_c_max), np.int32)
    for i in range(R):
        for j, (x, y) in enumerate(zip(contrib_x[i], contrib_y[i])):
            cx[i, j, :len(x)] = x
            cy[i, j, :len(y)] = y
    padded_rows = [row + [None] * (N - len(row)) for row in contrib_params]
    contrib_stack = _stack_trees(
        [_stack_trees(row, template) for row in padded_rows])
    # the flat-parameter round state: raveled ONCE here, donated to the
    # program, carried flat through every round
    contrib_flat, ravel_spec = tree_ravel(contrib_stack, batch_ndim=2)

    # ---- requester data + derived-schedule metadata -----------------------
    own_x, _ = _pad_stack([np.asarray(s.own_train[0], np.float32) for s in requesters],
                          max(len(s.own_train[0]) for s in requesters))
    own_y, _ = _pad_stack([np.asarray(s.own_train[1], np.int32) for s in requesters],
                          own_x.shape[1])
    test_x, test_mask = _pad_stack([np.asarray(s.own_test[0], np.float32) for s in requesters],
                                   max(len(s.own_test[0]) for s in requesters))
    test_y, _ = _pad_stack([np.asarray(s.own_test[1], np.int32) for s in requesters],
                           test_x.shape[1])

    n_own = np.array([len(s.own_train[0]) for s in requesters], np.int32)
    steps_max = max(schedule.fit_steps(int(n), cfg.batch_size) for n in n_own)

    ref_epochs = max(cfg.contributor_refresh_epochs, 0)
    ref_steps = max((schedule.fit_steps(len(x), cfg.batch_size)
                     for row in contrib_x for x in row), default=1)
    ref_seeds = np.zeros((R, N), np.int32)
    ref_n = np.zeros((R, N), np.int32)
    for i, cs in enumerate(contracts):
        for j, c in enumerate(cs):
            ref_seeds[i, j] = cfg.seed + c.device_id
            ref_n[i, j] = len(contrib_x[i][j])

    # ---- Phase.ACCOUNT constants (static per requester) -------------------
    num_params = tree_size(template)
    model_bytes = 4 * num_params if cfg.encrypt else tree_bytes(template)
    batteries = [s.battery or BatteryState() for s in requesters]
    e_round = np.array([cost.round_energy(
        n_contrib=len(cs), num_params=num_params, model_bytes=model_bytes,
        num_samples=len(spec.own_train[0]), epochs=cfg.epochs,
        n_devices=len(spec.neighborhood), encrypt=cfg.encrypt)
        for spec, cs in zip(requesters, contracts)], np.float32)
    capacity = np.array([b.capacity_j for b in batteries], np.float32)
    level0 = np.array([b.level for b in batteries], np.float32)
    eff = np.array([load_efficiency(cost.device.p_train, b.high_load_penalty,
                                    b.high_load_threshold_w) for b in batteries],
                   np.float32)

    # ---- the compiled program ---------------------------------------------
    arrays = dict(
        level0=jnp.asarray(level0), own_x=jnp.asarray(own_x),
        own_y=jnp.asarray(own_y), test_x=jnp.asarray(test_x),
        test_y=jnp.asarray(test_y), test_mask=jnp.asarray(test_mask),
        n_own=jnp.asarray(n_own), seed0=jnp.int32(cfg.seed),
        round_w=jnp.asarray(round_w),
        e_round=jnp.asarray(e_round), capacity=jnp.asarray(capacity),
        eff=jnp.asarray(eff),
        desired_accuracy=jnp.float32(cfg.desired_accuracy),
        battery_threshold=jnp.float32(cfg.battery_threshold))
    if ref_epochs > 0:
        arrays.update(cx=jnp.asarray(cx), cy=jnp.asarray(cy),
                      ref_seeds=jnp.asarray(ref_seeds),
                      ref_n=jnp.asarray(ref_n))
    staged = [contrib_flat] + [v for v in arrays.values() if hasattr(v, "nbytes")]
    staged_bytes = int(sum(int(v.nbytes) for v in staged))
    index_bytes = int(n_own.nbytes + ref_seeds.nbytes + ref_n.nbytes + 4)

    contrib_final, last_flat, level, stop_code, rounds_done, traces = _fleet_program(
        task, use_pallas, resolve_interpret(interpret), ref_epochs > 0,
        int(round_chunk), cfg.max_rounds, cfg.epochs, cfg.batch_size,
        steps_max, ref_epochs, ref_steps, ravel_spec, contrib_flat, arrays)
    acc_h, loss_h, bat_h, exec_h, body_h = (np.asarray(t) for t in traces)
    rounds_np = np.asarray(rounds_done)
    codes_np = np.asarray(stop_code)
    level_np = np.asarray(level)

    # contributor write-back: like the loop engine's in-place refresh,
    # each requester's contributor_states end up holding that session's
    # final (refresh-trained, frozen-once-stopped) contributor params.
    # Requesters sharing one states dict see the last writer's lanes.
    if ref_epochs > 0:
        contrib_tree = tree_unravel(ravel_spec, contrib_final)
        for i, (spec, cs) in enumerate(zip(requesters, contracts)):
            for j, c in enumerate(cs):
                spec.contributor_states[c.device_id]["params"] = (
                    jax.tree_util.tree_map(lambda l: l[i, j], contrib_tree))

    # ---- per-session views (loop-engine-compatible SessionResults) --------
    last_p = tree_unravel(ravel_spec, last_flat)
    sessions = []
    total_e = 0.0
    for i, (spec, cs, b0) in enumerate(zip(requesters, contracts, batteries)):
        r_i = int(rounds_np[i])
        report = cost.session(
            rounds=r_i, n_contrib=len(cs), num_params=num_params,
            model_bytes=model_bytes, num_samples=len(spec.own_train[0]),
            epochs=cfg.epochs, n_devices=len(spec.neighborhood),
            encrypt=cfg.encrypt)
        total_e += report.e_tot
        battery = dataclasses.replace(b0, level=float(level_np[i]))
        history = {"accuracy": [float(a) for a in acc_h[:r_i, i]],
                   "loss": [float(l) for l in loss_h[:r_i, i]],
                   "battery": [float(l) for l in bat_h[:r_i, i]]}
        sessions.append(SessionResult(
            accuracy=history["accuracy"][-1] if history["accuracy"] else 0.0,
            rounds=r_i, n_contributors=len(cs), report=report, battery=battery,
            history=history, stop_reason=protocol.stop_reason_name(codes_np[i]),
            params=jax.tree_util.tree_map(lambda l: l[i], last_p)))
    return FleetResult(
        sessions=sessions, rounds=rounds_np, stop_codes=codes_np,
        accuracy=np.array([s.accuracy for s in sessions], np.float32),
        battery_level=level_np, total_energy_j=float(total_e),
        history={"accuracy": acc_h, "loss": loss_h, "battery": bat_h,
                 "executed": exec_h, "round_executed": body_h},
        staged_host_bytes=staged_bytes, staged_index_bytes=index_bytes)
