import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and record memory / cost / collective stats.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, an unsupported collective, or an
inconsistent shard_map spec fails here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch debug-dense --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # full matrix
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
Options:
  --strategy {cfl,enfed,dfl_ring,dfl_mesh,none}   train aggregation schedule
  --neighborhood N                                EnFed nearby-device count
  --mla-absorbed                                  absorbed MLA decode variant
  --out results/dryrun                            JSON output directory
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, INPUT_SHAPES, get_config, shape_supported
from repro.core.topology import AggregationStrategy
from repro.launch import inputs as inp
from repro.launch.hlo_stats import collective_bytes, cost_summary, memory_summary
from repro.launch.mesh import client_axes_for, make_production_mesh
from repro.launch.steps import (fed_param_shardings, make_federated_train_step,
                                make_prefill_step, make_serve_step, num_clients,
                                stack_for_clients)
from repro.models import Transformer
from repro.optim import adam
from repro.sharding import param_specs, use_mesh
from repro.sharding.specs import input_specs_sharding

SDS = jax.ShapeDtypeStruct


def _sds_tree(shape_tree):
    return jax.tree_util.tree_map(lambda x: SDS(x.shape, x.dtype), shape_tree)


def lower_train(cfg, model, mesh, strategy_kind, neighborhood, compress=None):
    caxes = client_axes_for(cfg, mesh)
    C = num_clients(mesh, caxes)
    strategy = AggregationStrategy(kind=strategy_kind, client_axes=caxes,
                                   neighborhood_size=neighborhood,
                                   compress=compress)
    step, opt = make_federated_train_step(model, mesh, strategy, lr=1e-4)
    shp = INPUT_SHAPES["train_4k"]
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch = inp.train_inputs(cfg, shp["global_batch"], shp["seq_len"])

    if not caxes or strategy_kind == "none":
        opt_shape = jax.eval_shape(opt.init, params_shape)
        psh = param_specs(params_shape, mesh, fsdp=cfg.fsdp)
        osh = param_specs(opt_shape, mesh, fsdp=cfg.fsdp)
        bsh = inp.batch_input_shardings(batch, mesh)
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh, None))
        return jitted.lower(params_shape, opt_shape, batch,
                            SDS((max(C, 1),), jnp.float32)), C
    pf = jax.tree_util.tree_map(lambda x: SDS((C,) + x.shape, x.dtype), params_shape)
    opt_shape = jax.eval_shape(opt.init, params_shape)
    of = jax.tree_util.tree_map(lambda x: SDS((C,) + x.shape, x.dtype), opt_shape)
    psh = fed_param_shardings(pf, mesh, caxes, cfg.fsdp)
    osh = fed_param_shardings(of, mesh, caxes, cfg.fsdp)
    bsh = inp.batch_input_shardings(batch, mesh, client_stacked=True, client_axes=caxes)
    jitted = jax.jit(step, in_shardings=(psh, osh, bsh, None))
    return jitted.lower(pf, of, batch, SDS((C,), jnp.float32)), C


def lower_prefill(cfg, model, mesh, shape_name):
    shp = INPUT_SHAPES[shape_name]
    step = make_prefill_step(model)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch = inp.prefill_inputs(cfg, shp["global_batch"], shp["seq_len"])
    psh = param_specs(params_shape, mesh, fsdp=cfg.fsdp)
    bsh = inp.batch_input_shardings(batch, mesh)
    jitted = jax.jit(step, in_shardings=(psh, bsh))
    return jitted.lower(params_shape, batch)


def lower_decode(cfg, model, mesh, shape_name, mla_absorbed=False):
    shp = INPUT_SHAPES[shape_name]
    B, S = shp["global_batch"], shp["seq_len"]
    step = make_serve_step(model, mla_absorbed=mla_absorbed)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache = inp.cache_shapes(model, B, S)
    tokens = SDS((B, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    memory = inp.decode_memory(cfg, B, S)
    psh = param_specs(params_shape, mesh, fsdp=cfg.fsdp)
    csh = inp.cache_shardings(cache, mesh)
    tsh = inp.batch_input_shardings({"tokens": tokens}, mesh)["tokens"]
    args = (params_shape, cache, tokens, pos)
    shardings = (psh, csh, tsh, None)
    if memory is not None:
        msh = inp.cache_shardings({"m": memory}, mesh)["m"]
        jitted = jax.jit(step, in_shardings=shardings + (msh,))
        return jitted.lower(*args, memory)
    jitted = jax.jit(step, in_shardings=shardings)
    return jitted.lower(*args)


def run_one(arch: str, shape_name: str, multi_pod: bool, strategy: str = "cfl",
            neighborhood: int = 4, mla_absorbed: bool = False,
            moe_dispatch: str = None, mlstm_chunk: int = 0,
            compress: str = None) -> dict:
    import dataclasses as _dc
    cfg = get_config(arch)
    if moe_dispatch and cfg.moe is not None:
        cfg = cfg.replace(moe=_dc.replace(cfg.moe, dispatch=moe_dispatch))
    if mlstm_chunk:
        cfg = cfg.replace(mlstm_chunk=mlstm_chunk)
    rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
           "strategy": strategy, "status": "skipped", "mla_absorbed": mla_absorbed,
           "moe_dispatch": moe_dispatch, "mlstm_chunk": mlstm_chunk,
           "compress": compress}
    if not shape_supported(cfg, shape_name):
        rec["reason"] = "full-attention arch: long_500k decode skipped (DESIGN.md)"
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Transformer(cfg)
    kind = INPUT_SHAPES[shape_name]["kind"]
    t0 = time.time()
    with use_mesh(mesh):
        if kind == "train":
            lowered, C = lower_train(cfg, model, mesh, strategy, neighborhood,
                                     compress=compress)
            rec["num_clients"] = C
        elif kind == "prefill":
            lowered = lower_prefill(cfg, model, mesh, shape_name)
        else:
            lowered = lower_decode(cfg, model, mesh, shape_name, mla_absorbed)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
    rec.update(cost_summary(compiled))
    rec.update(memory_summary(compiled))
    rec.update(collective_bytes(compiled.as_text()))
    rec["n_devices"] = int(np.prod(list(mesh.shape.values())))
    rec["status"] = "ok"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--strategy", default="cfl",
                    choices=("cfl", "enfed", "dfl_ring", "dfl_mesh", "none"))
    ap.add_argument("--neighborhood", type=int, default=4)
    ap.add_argument("--compress", default=None, choices=(None, "int8"))
    ap.add_argument("--mla-absorbed", action="store_true")
    ap.add_argument("--moe-dispatch", default=None, choices=(None, "sort", "einsum", "ep"))
    ap.add_argument("--mlstm-chunk", type=int, default=0)
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = sorted(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = sorted(INPUT_SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}__{args.strategy}"
                if args.mla_absorbed:
                    tag += "__absorbed"
                if args.moe_dispatch:
                    tag += f"__{args.moe_dispatch}"
                if args.mlstm_chunk:
                    tag += f"__chunk{args.mlstm_chunk}"
                if args.compress:
                    tag += f"__{args.compress}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip cached] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = run_one(arch, shape, mp, args.strategy,
                                  args.neighborhood, args.mla_absorbed,
                                  args.moe_dispatch, args.mlstm_chunk,
                                  args.compress)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "strategy": args.strategy, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    n_fail += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                msg = rec["status"]
                if rec["status"] == "ok":
                    msg += (f" flops/dev={rec.get('flops', 0):.3e}"
                            f" coll={rec.get('total_collective_bytes', 0):.3e}B"
                            f" mem={rec.get('total_bytes_per_device', 0)/2**30:.2f}GiB"
                            f" compile={rec.get('compile_s')}s")
                print(f"[dryrun] {tag}: {msg}", flush=True)
    print(f"done ({n_fail} failures)")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
