"""Public op: fused LSTM cell, selectable implementation.

``lstm_cell`` has the exact signature the classifier's scan body expects,
so ``LSTMClassifierConfig(cell="pallas")`` swaps the hot loop in place.
"""

from __future__ import annotations

from repro.kernels.lstm_cell.kernel import lstm_cell_pallas
from repro.kernels.lstm_cell.ref import lstm_cell_ref


def lstm_cell(x, h, c, wx, wh, b, *, interpret=None):
    return lstm_cell_pallas(x, h, c, wx, wh, b, interpret=interpret)


__all__ = ["lstm_cell", "lstm_cell_pallas", "lstm_cell_ref"]
