"""EnFed core: the paper's contribution as a first-class feature.

Protocol (incentives, handshake, AES transport, Algorithm-1 round loop),
cost model (eqs. 4-7), the two execution engines (loop oracle + jit
fleet), the opportunistic mobility world, and the FL topologies.

The documented public import surface is the :mod:`repro.api` facade::

    from repro.api import Experiment, WorldSpec, MethodSpec, ExecutionSpec, RunResult

Those facade types are also re-exported here (lazily, via PEP 562 —
``repro.api`` itself imports these core submodules) so
``from repro.core import Experiment`` works; the engine-level
entrypoints below (``EnFedSession``, ``run_fleet``, the baseline
learners) remain for the facade to delegate to.
"""

from repro.core.aggregation import fedavg, masked_fedavg, masked_weighted_mean_stacked
from repro.core.battery import BatteryState
from repro.core.energy import CostModel, DeviceProfile, LinkProfile, EnergyReport
from repro.core.incentive import (
    NeighborDevice,
    Contract,
    select_contributors,
    participation_mask,
    make_fleet,
)
from repro.core.rounds import EnFedConfig, EnFedSession, SessionResult
from repro.core.federated import (
    SupervisedTask,
    CFLLearner,
    DFLLearner,
    FederatedTrainer,
    cloud_only_baseline,
    cloud_only_config,
)
from repro.core.adversary import AdversaryConfig
from repro.core.cadence import CadenceConfig
from repro.core.faults import FaultConfig
from repro.core.fleet import FleetResult, RequesterSpec, run_fleet
from repro.core.mobility import MobilityConfig
from repro.core.protocol import Phase
from repro.core.topology import AggregationStrategy, aggregate_updates, group_mixing_matrix

# repro.api facade types re-exported lazily (see __getattr__ below).
_API_EXPORTS = (
    "Experiment",
    "WorldSpec",
    "MethodSpec",
    "ExecutionSpec",
    "RunResult",
    "CompareResult",
    "register_method",
)

# The single consolidated public-API list: engine-level core names plus
# the repro.api facade surface.
__all__ = [
    # aggregation + battery + cost model
    "fedavg", "masked_fedavg", "masked_weighted_mean_stacked",
    "BatteryState", "CostModel", "DeviceProfile", "LinkProfile", "EnergyReport",
    # incentives / world
    "NeighborDevice", "Contract", "select_contributors", "participation_mask",
    "make_fleet", "MobilityConfig", "FaultConfig", "CadenceConfig",
    "AdversaryConfig",
    # EnFed engines + protocol vocabulary
    "EnFedConfig", "EnFedSession", "SessionResult",
    "FleetResult", "RequesterSpec", "run_fleet", "Phase",
    # baselines (EnFedConfig-plumbed; legacy shims kept)
    "SupervisedTask", "CFLLearner", "DFLLearner", "FederatedTrainer",
    "cloud_only_baseline", "cloud_only_config",
    # topologies
    "AggregationStrategy", "aggregate_updates", "group_mixing_matrix",
    # repro.api facade (lazy)
    *_API_EXPORTS,
]


def __getattr__(name: str):
    """Lazy facade re-export: ``repro.api`` imports these submodules, so
    importing it eagerly here would be a cycle; resolving on first
    access keeps both import orders working."""
    if name in _API_EXPORTS:
        import repro.api as _api

        return getattr(_api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
