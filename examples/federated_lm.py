"""Federate an architecture-zoo language model with EnFed.

End-to-end driver: picks an architecture from the registry (reduced
preset for CPU), simulates an opportunistic client fleet with incentives
and batteries, and trains with the EnFed neighborhood aggregation —
delegates to the production launcher.

  PYTHONPATH=src python examples/federated_lm.py --arch debug-dense --steps 30
"""

import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="debug-dense")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--strategy", default="enfed")
    args = ap.parse_args()
    return train_mod.main([
        "--arch", args.arch, "--preset", "smoke",
        "--steps", str(args.steps), "--clients", str(args.clients),
        "--strategy", args.strategy, "--neighborhood", "2",
        "--log-every", "5",
    ])


if __name__ == "__main__":
    raise SystemExit(main())
