"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] —
small MoE: 32 experts, top-8 routing, ~400M active params.

Assigned spec: 24L, d_model=1024, 16H (GQA kv=8), expert d_ff=512,
vocab=49155.  Full attention => long_500k skipped.
vocab 49155 is deliberately not divisible by the 16-way model axis —
the sharding rules fall back to the d_model axis for the embedding
(exercised in tests).
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    block_pattern=("attn",),
    moe=MoEConfig(num_experts=32, num_experts_per_tok=8,
                  num_shared_experts=0, d_ff_expert=512),
    dtype="bfloat16",
)
